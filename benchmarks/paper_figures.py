"""Paper-figure benchmarks (Figs. 2, 4, 5, 6, 7).

Each function reproduces one figure's experiment with two instruments:
  * the conflict model (core.aliasing) -- the analytic curve the paper's
    hardware produced (we have no T2; the model IS the reproduction target,
    and tests/test_aliasing.py pins its claims),
  * wall-clock of the jitted kernels on this host where meaningful
    (CPU numbers are smoke-level, not roofline).

Output: ``name,us_per_call,derived`` CSV rows via benchmarks.run.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core.aliasing import InterleavedMemoryModel, Stream
from repro.core.autotune import StreamSignature, plan_streams
from repro.core.segmented import SegmentedArray
from repro.kernels.jacobi import ops as jops
from repro.kernels.lbm import ops as lops
from repro.kernels.stream import ops as sops
from repro.kernels.triad import ops as tops

M = InterleavedMemoryModel()


def _time(fn, *args, iters=3) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / iters * 1e6


def fig2_stream_offset() -> list[tuple[str, float, str]]:
    """STREAM triad bandwidth vs offset (Fig. 2): model curve extrema."""
    rows = []
    curve = M.stream_triad_curve(n_elements=2 ** 22, offsets=range(64),
                                 n_threads=64)
    vals = np.array(list(curve.values()))
    rows.append(("fig2.model.offset0_gbs", 0.0, f"{curve[0]:.2f}"))
    rows.append(("fig2.model.offset32_gbs", 0.0, f"{curve[32]:.2f}"))
    rows.append(("fig2.model.envelope_gbs", 0.0, f"{vals.max():.2f}"))
    rows.append(("fig2.model.envelope_fraction", 0.0,
                 f"{(vals >= vals.max() - 1e-9).mean():.2f}"))
    n = 2 ** 20
    a = jnp.zeros(n)
    b = jnp.ones(n)
    us = _time(lambda x, y: api.launch("stream.triad", x, y, s=3.0), a, b)
    rows.append(("fig2.cpu.triad_1M", us,
                 f"{sops.bytes_moved('triad', n) / (us * 1e-6) / 1e9:.2f}GB/s"))
    return rows


def fig4_vector_triad() -> list[tuple[str, float, str]]:
    """Vector triad vs alignment (Fig. 4): plain vs page-aligned vs skewed."""
    rows = []
    sig = StreamSignature(n_read=3, n_write=1)
    plan = plan_streams(sig, M)
    # page-aligned (all arrays same 8k phase) = paper's forced-worst case
    worst = M.balance([Stream(0, "write"), Stream(0, "read"),
                       Stream(0, "read"), Stream(0, "read")])
    rows.append(("fig4.model.page_aligned_balance", 0.0, f"{worst:.3f}"))
    rows.append(("fig4.model.skewed_balance", 0.0,
                 f"{plan.predicted_balance:.3f}"))
    rows.append(("fig4.model.skew_offsets_bytes", 0.0,
                 "/".join(map(str, plan.offsets_bytes))))
    n = 2 ** 20
    b, c, d = (jnp.full(n, float(i)) for i in range(3))
    us = _time(lambda x, y, z: api.launch("triad", x, y, z), b, c, d)
    rows.append(("fig4.cpu.triad_aligned_1M", us,
                 f"{tops.triad_bytes(n, 4, rfo=False) / (us * 1e-6) / 1e9:.2f}GB/s"))
    us2 = _time(lambda x, y, z: tops.vector_triad_phased(
        x, y, z, phases=(32, 64, 96)), b, c, d)
    rows.append(("fig4.cpu.triad_phased_1M", us2, f"{us2 / us:.2f}x_aligned"))
    return rows


def fig5_segmented_overhead() -> list[tuple[str, float, str]]:
    """Segmented-iterator overhead vs plain (Fig. 5): expect ~1.0x."""
    rows = []
    for n in (10_000, 100_000, 1_000_000):
        b, c, d = (jnp.full(n, float(i)) for i in range(3))
        us_plain = _time(lambda x, y, z: api.launch("triad", x, y, z), b, c, d)
        segs = [SegmentedArray.from_flat(v, 8, align=128, shift=16)
                for v in (jnp.zeros(n), b, c, d)]
        fn = jax.jit(tops.vector_triad_segmented)
        us_seg = _time(fn, *segs)
        rows.append((f"fig5.overhead_n{n}", us_seg,
                     f"{us_seg / max(us_plain, 1e-9):.2f}x_plain"))
    return rows


def fig6_jacobi() -> list[tuple[str, float, str]]:
    """2D Jacobi (Fig. 6): MLUPs + the analytic layout parameters."""
    rows = []
    sig = StreamSignature(n_read=1, n_write=1)  # streaming rows (halo cached)
    plan = plan_streams(sig, M)
    rows.append(("fig6.model.segment_shift_bytes", 0.0,
                 str(plan.segment_shift_bytes)))
    rows.append(("fig6.model.align_bytes", 0.0, str(plan.align_bytes)))
    for n in (256, 1024):
        g = jnp.zeros((n, n)).at[0].set(1.0)
        us = _time(lambda x: jops.jacobi_sweeps(x, 10), g)
        mlups = (n - 2) ** 2 * 10 / (us * 1e-6) / 1e6
        rows.append((f"fig6.cpu.jacobi_{n}x{n}_10it", us, f"{mlups:.1f}MLUPs"))
    return rows


def fig7_lbm_layout() -> list[tuple[str, float, str]]:
    """LBM layouts (Fig. 7): model balance + CPU step for both layouts."""
    rows = []
    for n in (100, 96, 64, 50):
        best, s = lops.layout_balance_scores(n=n)
        rows.append((f"fig7.model.N{n}", 0.0,
                     f"best={best};soa={s['soa']:.2f};ivjk={s['ivjk']:.2f}"))
    f = lops.init_equilibrium(32, jnp.float32)
    for layout in ("soa", "ivjk"):
        us = _time(lambda x: lops.lbm_run(x, 1.2, 5, layout=layout), f)
        mlups = 32 ** 3 * 5 / (us * 1e-6) / 1e6
        rows.append((f"fig7.cpu.lbm32_{layout}_5it", us, f"{mlups:.2f}MLUPs"))
    return rows


ALL = [fig2_stream_offset, fig4_vector_triad, fig5_segmented_overhead,
       fig6_jacobi, fig7_lbm_layout]
