"""Benchmark driver: one section per paper table/figure + the roofline
report.  Prints ``name,us_per_call,derived`` CSV (assignment convention)
by default; ``--json [PATH]`` emits a versioned machine-readable document
instead so CI can archive the perf trajectory as ``BENCH_*.json``
artifacts and diff runs across commits."""
from __future__ import annotations

import argparse
import json
import os
import sys

if __package__ in (None, ""):   # script invocation: python benchmarks/run.py
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

BENCH_FORMAT = "repro.bench"
BENCH_VERSION = 1


def collect_rows() -> list[tuple[str, float, str]]:
    """Every benchmark row: paper figures, the MoE skew table, roofline."""
    from benchmarks import moe_skew, paper_figures, roofline, serving_load

    rows: list[tuple[str, float, str]] = []
    for fn in paper_figures.ALL:
        rows.extend(fn())
    rows.extend(moe_skew.rows())
    rows.extend(roofline.rows())
    rows.extend(serving_load.rows())
    return rows


def to_document(rows) -> dict:
    """Versioned schema for archived benchmark runs.  ``derived`` stays a
    string (each section formats its own GB/s / GLUP/s / ratio payload);
    consumers key on (format, version) before parsing further."""
    import jax

    return {
        "format": BENCH_FORMAT,
        "version": BENCH_VERSION,
        "backend": jax.default_backend(),
        "jax_version": jax.__version__,
        "rows": [
            {"name": name, "us_per_call": round(float(us), 2),
             "derived": str(derived)}
            for name, us, derived in rows
        ],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="run every benchmark section")
    ap.add_argument("--json", nargs="?", const="-", default=None,
                    metavar="PATH",
                    help="emit a versioned JSON document (to PATH, or "
                         "stdout with no argument) instead of CSV")
    args = ap.parse_args(argv)

    rows = collect_rows()
    if args.json is not None:
        doc = to_document(rows)
        if args.json == "-":
            json.dump(doc, sys.stdout, indent=1)
            print()
        else:
            with open(args.json, "w") as f:
                json.dump(doc, f, indent=1)
            print(f"wrote {len(doc['rows'])} rows -> {args.json}")
        return 0
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
