"""Benchmark driver: one section per paper table/figure + the roofline
report.  Prints ``name,us_per_call,derived`` CSV (assignment convention)."""
from __future__ import annotations


def main() -> None:
    from benchmarks import moe_skew, paper_figures, roofline

    print("name,us_per_call,derived")
    for fn in paper_figures.ALL:
        for name, us, derived in fn():
            print(f"{name},{us:.2f},{derived}")
    for name, us, derived in moe_skew.rows():
        print(f"{name},{us:.2f},{derived}")
    for name, us, derived in roofline.rows():
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
